"""Model blocks: attention (+KV caches), SwiGLU FFN, MoE, mamba (SSD form),
mLSTM, sLSTM. Each block exposes:

  specs(cfg)                        -> Spec tree (one layer, unstacked)
  fwd_seq(p, x, ctx, cfg)           -> (x, cache_entry | None)   train/prefill
  fwd_dec(p, x, state, shared, cfg) -> (x, new_state)            decode
  init_state(cfg, batch, cache_len) -> zeroed decode-state entry (or specs)

Conventions: x is [B, S, D] (seq modes) or [B, D] (decode). ``ctx`` carries
positions; ``shared`` carries decode positions/validity shared by all layers.
Caches store K/V **post-RoPE** at absolute positions.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import (
    get_mesh, with_sharding_constraint, num_data_shards, model_axis_size,
    spec_for, get_rules,
)
from repro.models import attention_ops as aops
from repro.models.common import Spec, dtype_of


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [..., S, H, D] or [..., H, D] (decode); positions [..., S] or [...]."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, half]
    angles = jnp.expand_dims(angles, axis=-2)                  # broadcast heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _einsum(subs, *args):
    return jnp.einsum(subs, *args, preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# attention block (self-attention; cross-attention variant for enc-dec)
# ---------------------------------------------------------------------------

class Attention:
    """GQA attention with RoPE, optional sliding window, dense or ring cache."""

    def __init__(self, cross: bool = False):
        self.cross = cross

    def specs(self, cfg: ModelConfig) -> Dict[str, Spec]:
        d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
        dt = dtype_of(cfg)
        return {
            "wq": Spec((d, hq, hd), ("embed", "heads", "head_dim"), dt, fan_in=d),
            "wk": Spec((d, hkv, hd), ("embed", "kv_heads", "head_dim"), dt, fan_in=d),
            "wv": Spec((d, hkv, hd), ("embed", "kv_heads", "head_dim"), dt, fan_in=d),
            "wo": Spec((hq, hd, d), ("heads", "head_dim", "embed"), dt, fan_in=hq * hd),
        }

    def cache_len(self, cfg: ModelConfig, max_context: int) -> int:
        if cfg.sliding_window:
            return min(max_context, cfg.sliding_window)
        return max_context

    def init_state(self, cfg: ModelConfig, batch: int, max_context: int):
        hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        s = self.cache_len(cfg, max_context)
        dt = dtype_of(cfg)
        # batch=1 long-context cells shard the KV sequence over every mesh
        # axis (pure context parallelism); otherwise batch covers data axes.
        seq_logical = "kv_seq_full" if batch == 1 else "kv_seq"
        return {
            "k": Spec((batch, s, hkv, hd), ("batch", seq_logical, None, None), dt, "zeros"),
            "v": Spec((batch, s, hkv, hd), ("batch", seq_logical, None, None), dt, "zeros"),
        }

    def _qkv(self, p, x, cfg):
        q = _einsum("...d,dhk->...hk", x, p["wq"]).astype(x.dtype)
        k = _einsum("...d,dhk->...hk", x, p["wk"]).astype(x.dtype)
        v = _einsum("...d,dhk->...hk", x, p["wv"]).astype(x.dtype)
        return q, k, v

    def fwd_seq(self, p, x, ctx, cfg: ModelConfig):
        """Train / prefill over a full sequence. ctx: dict with
        'positions' [B,S]; for cross-attn: 'enc_out' [B,Senc,D];
        'bidirectional' flag for encoder self-attention."""
        positions = ctx["positions"]
        if self.cross:
            kv_src = ctx["enc_out"]
            q = _einsum("bsd,dhk->bshk", x, p["wq"]).astype(x.dtype)
            k = _einsum("bsd,dhk->bshk", kv_src, p["wk"]).astype(x.dtype)
            v = _einsum("bsd,dhk->bshk", kv_src, p["wv"]).astype(x.dtype)
            out = aops.flash_attention(q, k, v, causal=False)
            cache = {"k": k, "v": v}           # immutable cross KV for decode
        else:
            q, k, v = self._qkv(p, x, cfg)
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
            q = with_sharding_constraint(q, ("batch", "seq_cp", "act_heads", None))
            causal = not ctx.get("bidirectional", False)
            out = aops.flash_attention(
                q, k, v, q_pos=positions, kv_pos=positions,
                causal=causal, window=cfg.sliding_window)
            cache = {"k": k, "v": v}
        y = _einsum("bshk,hkd->bsd", out, p["wo"]).astype(x.dtype)
        return y, cache

    def seq_cache_to_state(self, cfg, cache, max_context: int):
        """Pad prefill K/V [B,S,...] into a decode cache [B,cache_len,...].
        For ring (SWA) caches keeps the last `window` tokens at their slots."""
        k, v = cache["k"], cache["v"]
        b, s = k.shape[0], k.shape[1]
        s_c = self.cache_len(cfg, max_context)
        if self.cross:
            return {"k": k, "v": v}
        if cfg.sliding_window and s >= s_c:
            # token t lives at slot t % window
            last = s - s_c
            idx = (last + jnp.arange(s_c)) % s_c
            take = last + jnp.arange(s_c)
            order = jnp.argsort(idx)
            return {"k": k[:, take[order]], "v": v[:, take[order]]}
        pad = [(0, 0), (0, s_c - s), (0, 0), (0, 0)]
        return {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}

    def fwd_dec(self, p, x, state, shared, cfg: ModelConfig):
        """Decode one token. x [B, D]; state {k,v} [B,Sc,...];
        shared: pos [B], kv_pos [B,Sc], kv_valid [B,Sc], slot [B]
        (+ cross_pos/cross_valid and state['cross'] for enc-dec)."""
        pos = shared["pos"]
        if self.cross:
            q = _einsum("bd,dhk->bhk", x, p["wq"]).astype(x.dtype)
            out = aops.decode_attention(
                q, state["k"], state["v"], pos,
                shared["cross_pos"], shared["cross_valid"], causal=False)
            y = _einsum("bhk,hkd->bd", out, p["wo"]).astype(x.dtype)
            return y, state
        q, k_new, v_new = self._qkv(p, x, cfg)
        q = rope(q, pos, cfg.rope_theta)
        k_new = rope(k_new, pos, cfg.rope_theta)
        slot = shared["slot"]                      # [B] write index
        write = lambda c, n, s: jax.vmap(
            lambda cb, nb, sb: jax.lax.dynamic_update_slice(
                cb, nb[None], (sb, 0, 0)))(c, n, s)
        k_cache = write(state["k"], k_new, slot)
        v_cache = write(state["v"], v_new, slot)
        mesh = get_mesh()
        kv_axes = _kv_shard_axes(mesh, k_cache.shape)
        if kv_axes:
            batch_axes = _batch_shard_axes(mesh, x.shape[0], kv_axes)
            out = aops.distributed_decode_attention(
                mesh, kv_axes, q, k_cache, v_cache, pos,
                shared["kv_pos"], shared["kv_valid"],
                window=cfg.sliding_window, batch_axes=batch_axes)
        else:
            out = aops.decode_attention(
                q, k_cache, v_cache, pos, shared["kv_pos"], shared["kv_valid"],
                window=cfg.sliding_window)
        y = _einsum("bhk,hkd->bd", out, p["wo"]).astype(x.dtype)
        return y, {"k": k_cache, "v": v_cache}


def _kv_shard_axes(mesh, kv_shape) -> Tuple[str, ...]:
    """Which mesh axes shard the KV-cache sequence dim (flash-decode)."""
    if mesh is None or "model" not in mesh.axis_names:
        return ()
    if mesh.shape["model"] == 1:
        return ()
    rules = get_rules()
    logical = ("batch", "kv_seq_full" if kv_shape[0] == 1 else "kv_seq", None, None)
    spec = spec_for(logical, kv_shape, mesh, rules)
    seq_part = spec[1] if len(spec) > 1 else None
    if seq_part is None:
        return ()
    return seq_part if isinstance(seq_part, tuple) else (seq_part,)


def _batch_shard_axes(mesh, batch: int, kv_axes) -> Tuple[str, ...]:
    axes = []
    n = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names and ax not in kv_axes:
            size = mesh.shape[ax]
            if size > 1 and batch % (n * size) == 0:
                axes.append(ax)
                n *= size
    return tuple(axes)


# ---------------------------------------------------------------------------
# dense FFN (SwiGLU)
# ---------------------------------------------------------------------------

class SwiGLU:
    def specs(self, cfg: ModelConfig) -> Dict[str, Spec]:
        d, f = cfg.d_model, cfg.d_ff
        dt = dtype_of(cfg)
        return {
            "w_in": Spec((d, f), ("embed", "mlp"), dt, fan_in=d),
            "w_gate": Spec((d, f), ("embed", "mlp"), dt, fan_in=d),
            "w_out": Spec((f, d), ("mlp", "embed"), dt, fan_in=f),
        }

    def __call__(self, p, x):
        h = _einsum("...d,df->...f", x, p["w_in"])
        g = _einsum("...d,df->...f", x, p["w_gate"])
        h = jax.nn.silu(g) * h
        return _einsum("...f,fd->...d", h, p["w_out"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE FFN: group-local sort-gather dispatch (no one-hot einsum), EP over
# the `model` axis, FSDP over `expert_mlp`. Capacity auto-raises for tiny
# token counts so decode never drops tokens.
# ---------------------------------------------------------------------------

class MoE:
    def specs(self, cfg: ModelConfig) -> Dict[str, Spec]:
        m = cfg.moe
        d, e, f = cfg.d_model, m.num_experts, m.d_expert
        dt = dtype_of(cfg)
        return {
            "router": Spec((d, e), ("embed", None), jnp.float32, fan_in=d),
            "w_in": Spec((e, d, f), ("experts", "expert_mlp", "expert_ff"), dt, fan_in=d),
            "w_gate": Spec((e, d, f), ("experts", "expert_mlp", "expert_ff"), dt, fan_in=d),
            "w_out": Spec((e, f, d), ("experts", "expert_ff", "expert_mlp"), dt, fan_in=f),
        }

    @staticmethod
    def _capacity(tokens_per_group: int, m) -> int:
        lam = tokens_per_group * m.top_k / m.num_experts
        c = int(math.ceil(lam * m.capacity_factor))
        # Poisson +3σ floor: at decode-scale token counts the relative load
        # fluctuation is large and cf alone drops ~3% of assignments
        # (tests/test_moe_capacity_stats.py); +3σ keeps drops <0.1% while
        # adding nothing at train scale where cf·λ dominates.
        c3 = int(math.ceil(lam + 3.0 * math.sqrt(max(lam, 1e-9))))
        return min(tokens_per_group, max(c, c3, m.min_capacity))

    def __call__(self, p, x, cfg: ModelConfig, return_stats: bool = False):
        """x [..., D] -> [..., D] (+ aux loss stored on .aux).

        ``return_stats=True`` additionally returns the per-expert routing
        assignment counts [num_experts] (pre-capacity, summed over all
        top_k slots) — the raw signal ``ExpertRoutingStats`` smooths for
        expert-granular remapping.
        """
        m = cfg.moe
        orig_shape = x.shape
        d = orig_shape[-1]
        t = int(np.prod(orig_shape[:-1]))
        xf = x.reshape(t, d)
        mesh = get_mesh()
        shards = num_data_shards(mesh) if mesh is not None else 1
        # Decode-adaptive grouping (§Perf iteration 1): with few tokens,
        # per-data-shard groups multiply the capacity padding by the group
        # count (G groups x E experts x min-capacity slots for ~t*k useful
        # assignments). One global group bounds padding at E*C ~ 3x useful
        # instead of G*E*C ~ 48x.
        if t * m.top_k <= 8 * m.num_experts:
            g = 1
        else:
            g = math.gcd(t, shards) or 1
        tg = t // g
        cap = self._capacity(tg, m)
        xg = xf.reshape(g, tg, d)
        xg = with_sharding_constraint(xg, ("batch", None, None))

        logits = _einsum("gtd,de->gte", xg, p["router"])          # f32
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, m.top_k)              # [g,tg,k]
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

        # ---- sort-gather dispatch ----------------------------------------
        flat_e = top_e.reshape(g, tg * m.top_k)                   # expert ids
        flat_w = top_p.reshape(g, tg * m.top_k)
        flat_tok = jnp.broadcast_to(
            jnp.arange(tg, dtype=jnp.int32)[:, None], (tg, m.top_k)
        ).reshape(tg * m.top_k)
        order = jnp.argsort(flat_e, axis=-1, stable=True)         # [g, tg*k]
        sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
        sorted_tok = flat_tok[order]                              # [g, tg*k]
        sorted_w = jnp.take_along_axis(flat_w, order, axis=-1)
        # position within expert = rank - first-rank-of-that-expert
        ar = jnp.arange(tg * m.top_k, dtype=jnp.int32)
        first = jax.vmap(
            lambda se: jnp.searchsorted(se, jnp.arange(m.num_experts), side="left")
        )(sorted_e)                                               # [g, E]
        pos_in_e = ar[None, :] - jnp.take_along_axis(first, sorted_e, axis=-1)
        ok = pos_in_e < cap
        slot = jnp.where(ok, sorted_e * cap + pos_in_e, m.num_experts * cap)
        # dispatch_idx[e, c] = source token (tg = padding row)
        disp = jnp.full((g, m.num_experts * cap + 1), tg, jnp.int32)
        disp = jax.vmap(lambda d_, s_, t_: d_.at[s_].set(t_))(disp, slot, sorted_tok)
        disp = disp[:, :-1].reshape(g, m.num_experts, cap)
        wcomb = jnp.zeros((g, m.num_experts * cap + 1), flat_w.dtype)
        wcomb = jax.vmap(lambda w_, s_, v_: w_.at[s_].set(v_))(wcomb, slot, sorted_w)
        wcomb = wcomb[:, :-1].reshape(g, m.num_experts, cap)

        xpad = jnp.concatenate([xg, jnp.zeros((g, 1, d), xg.dtype)], axis=1)
        xd = jnp.take_along_axis(
            xpad[:, :, None, :], disp.reshape(g, -1, 1, 1), axis=1
        ).reshape(g, m.num_experts, cap, d)
        xd = with_sharding_constraint(xd, ("batch", "experts", None, None))

        h = _einsum("gecd,edf->gecf", xd, p["w_in"])
        gate = _einsum("gecd,edf->gecf", xd, p["w_gate"])
        h = jax.nn.silu(gate) * h
        yd = _einsum("gecf,efd->gecd", h, p["w_out"]).astype(xg.dtype)
        yd = yd * wcomb[..., None].astype(yd.dtype)
        yd = with_sharding_constraint(yd, ("batch", "experts", None, None))

        # ---- combine: scatter-add back to token order --------------------
        out = jnp.zeros((g, tg + 1, d), yd.dtype)
        out = jax.vmap(lambda o_, i_, v_: o_.at[i_].add(v_))(
            out, disp.reshape(g, -1), yd.reshape(g, -1, d))
        out = out[:, :tg]

        # load-balance aux loss (Switch): E * sum(frac_tokens * frac_probs)
        me = probs.mean(axis=(0, 1))
        one_hot_top1 = jax.nn.one_hot(top_e[..., 0], m.num_experts)
        ce = one_hot_top1.reshape(-1, m.num_experts).mean(axis=0)
        aux = m.num_experts * jnp.sum(me * ce)
        if return_stats:
            counts = jnp.sum(
                jax.nn.one_hot(top_e.reshape(-1), m.num_experts), axis=0)
            return out.reshape(orig_shape), aux, counts
        return out.reshape(orig_shape), aux


# ---------------------------------------------------------------------------
# Chunked scalar-decay linear attention (SSD): shared by mamba & mLSTM.
#   y_t = q_t . S_t ;  S_t = a_t * S_{t-1} + k_t v_t^T        (per head)
# ---------------------------------------------------------------------------

def ssd_chunked(
    q: jax.Array,        # [B, T, H, dk]
    k: jax.Array,        # [B, T, H, dk]
    v: jax.Array,        # [B, T, H, dv]
    log_a: jax.Array,    # [B, T, H]  (log decay in (-inf, 0])
    chunk: int,
    init_state: Optional[jax.Array] = None,  # [B, H, dk, dv]
) -> Tuple[jax.Array, jax.Array]:
    """Chunkwise-parallel linear recurrence (mamba-2 SSD / GLA style).
    Returns (y [B,T,H,dv], final_state [B,H,dk,dv]). fp32 internally."""
    b, t, h, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, t)
    while t % chunk:          # largest divisor of t not above the request
        chunk -= 1
    n = t // chunk
    qc = q.reshape(b, n, chunk, h, dk).astype(jnp.float32)
    kc = k.reshape(b, n, chunk, h, dk).astype(jnp.float32)
    vc = v.reshape(b, n, chunk, h, dv).astype(jnp.float32)
    la = log_a.reshape(b, n, chunk, h).astype(jnp.float32)

    cum = jnp.cumsum(la, axis=2)                     # [B,n,L,H] inclusive
    total = cum[:, :, -1]                            # [B,n,H]

    if init_state is None:
        init_state = jnp.zeros((b, h, dk, dv), jnp.float32)

    @jax.checkpoint
    def body(s, xs):
        # checkpointed: AD through the chunk scan then saves only the [B,H,
        # dk,dv] carry per chunk and recomputes the [L,L] scores in bwd
        # (otherwise residuals would be O(T*L) per layer).
        qi, ki, vi, cumi, toti = xs                  # [B,L,H,*], [B,L,H], [B,H]
        # inter-chunk: y_inter_t = (q_t * exp(cum_t)) . S_prev
        q_dec = qi * jnp.exp(cumi)[..., None]
        y_inter = _einsum("blhk,bhkv->blhv", q_dec, s)
        # intra-chunk: scores[t,s] = q_t.k_s * exp(cum_t - cum_s), t >= s
        scores = _einsum("blhk,bmhk->bhlm", qi, ki)
        decay = cumi[:, :, None, :] - cumi[:, None, :, :]     # [B,l,m,H]
        decay = jnp.moveaxis(decay, -1, 1)                    # [B,H,l,m]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        scores = jnp.where(mask, scores * jnp.exp(decay), 0.0)
        y_intra = _einsum("bhlm,bmhv->blhv", scores, vi)
        # state update: S = exp(total) S + sum_s exp(total - cum_s) k_s v_s^T
        k_dec = ki * jnp.exp(toti[:, None] - cumi)[..., None]
        s_new = s * jnp.exp(toti)[..., None, None] + _einsum(
            "blhk,blhv->bhkv", k_dec, vi)
        return s_new, y_inter + y_intra

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (qc, kc, vc, cum, total))
    final, y = jax.lax.scan(body, init_state, xs)
    y = jnp.moveaxis(y, 0, 1).reshape(b, t, h, dv)
    return y, final


def ssd_decode_step(q, k, v, log_a, state):
    """One-token recurrence. q/k [B,H,dk], v [B,H,dv], log_a [B,H],
    state [B,H,dk,dv] -> (y [B,H,dv], new_state)."""
    a = jnp.exp(log_a.astype(jnp.float32))[..., None, None]
    state = state * a + _einsum(
        "bhk,bhv->bhkv", k.astype(jnp.float32), v.astype(jnp.float32))
    y = _einsum("bhk,bhkv->bhv", q.astype(jnp.float32), state)
    return y, state


# ---------------------------------------------------------------------------
# Mamba mixer (SSD / mamba-2 style: scalar-per-head decay, MXU-friendly).
# DESIGN.md records this as the TPU adaptation of the paper's mamba baseline.
# ---------------------------------------------------------------------------

class Mamba:
    HEAD_DIM = 64

    def dims(self, cfg: ModelConfig):
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        n_heads = d_in // self.HEAD_DIM
        return d_in, n_heads, s.d_state

    def specs(self, cfg: ModelConfig) -> Dict[str, Spec]:
        d = cfg.d_model
        d_in, h, n = self.dims(cfg)
        s = cfg.ssm
        dt = dtype_of(cfg)
        return {
            "in_proj": Spec((d, 2 * d_in), ("embed", "ssm_inner"), dt, fan_in=d),
            "conv_w": Spec((s.d_conv, d_in), ("conv", "ssm_inner"), dt, "small_normal"),
            "bc_proj": Spec((d, 2 * n), ("embed", None), dt, fan_in=d),
            "dt_proj": Spec((d, h), ("embed", "heads"), dt, fan_in=d),
            "dt_bias": Spec((h,), ("heads",), jnp.float32, "zeros"),
            "a_log": Spec((h,), ("heads",), jnp.float32, "zeros"),
            "d_skip": Spec((h,), ("heads",), jnp.float32, "ones"),
            "out_proj": Spec((d_in, d), ("ssm_inner", "embed"), dt, fan_in=d_in),
        }

    def init_state(self, cfg: ModelConfig, batch: int, _max_context: int):
        d_in, h, n = self.dims(cfg)
        s = cfg.ssm
        return {
            "ssm": Spec((batch, h, n, self.HEAD_DIM),
                        ("batch", None, None, None), jnp.float32, "zeros"),
            "conv": Spec((batch, s.d_conv - 1, d_in),
                         ("batch", None, "ssm_inner"), dtype_of(cfg), "zeros"),
        }

    def _proj_gates(self, p, x):
        d_in = p["out_proj"].shape[0]
        xz = _einsum("...d,de->...e", x, p["in_proj"]).astype(x.dtype)
        return xz[..., :d_in], xz[..., d_in:]

    def fwd_seq(self, p, x, ctx, cfg: ModelConfig):
        b, t, _ = x.shape
        d_in, h, n = self.dims(cfg)
        s = cfg.ssm
        xi, z = self._proj_gates(p, x)
        # causal depthwise conv over time
        conv_tail = xi[:, -(s.d_conv - 1):, :]
        xpad = jnp.pad(xi, ((0, 0), (s.d_conv - 1, 0), (0, 0)))
        xc = sum(
            xpad[:, i:i + t, :] * p["conv_w"][i][None, None, :]
            for i in range(s.d_conv))
        xc = jax.nn.silu(xc)
        bc = _einsum("btd,dn->btn", x, p["bc_proj"]).astype(x.dtype)
        b_mat, c_mat = bc[..., :n], bc[..., n:]
        dt = jax.nn.softplus(
            _einsum("btd,dh->bth", x, p["dt_proj"]) + p["dt_bias"])
        a = -jnp.exp(p["a_log"])                                   # [h] < 0
        log_a = dt * a                                             # [b,t,h]
        xh = xc.reshape(b, t, h, self.HEAD_DIM)
        v = xh * dt[..., None]
        q = jnp.broadcast_to(c_mat[:, :, None, :], (b, t, h, n))
        k = jnp.broadcast_to(b_mat[:, :, None, :], (b, t, h, n))
        y, final = ssd_chunked(q, k, v, log_a, s.chunk_size)
        y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
        y = y.reshape(b, t, d_in).astype(x.dtype) * jax.nn.silu(z)
        out = _einsum("bte,ed->btd", y, p["out_proj"]).astype(x.dtype)
        return out, {"ssm": final, "conv": conv_tail}

    def fwd_dec(self, p, x, state, shared, cfg: ModelConfig):
        bsz = x.shape[0]
        d_in, h, n = self.dims(cfg)
        s = cfg.ssm
        xi, z = self._proj_gates(p, x)                 # [B, d_in]
        window = jnp.concatenate([state["conv"], xi[:, None, :]], axis=1)
        xc = _einsum("bcd,cd->bd", window, p["conv_w"]).astype(x.dtype)
        xc = jax.nn.silu(xc)
        bc = _einsum("bd,dn->bn", x, p["bc_proj"]).astype(x.dtype)
        b_mat, c_mat = bc[..., :n], bc[..., n:]
        dt = jax.nn.softplus(_einsum("bd,dh->bh", x, p["dt_proj"]) + p["dt_bias"])
        log_a = dt * (-jnp.exp(p["a_log"]))
        xh = xc.reshape(bsz, h, self.HEAD_DIM)
        v = xh * dt[..., None]
        q = jnp.broadcast_to(c_mat[:, None, :], (bsz, h, n))
        k = jnp.broadcast_to(b_mat[:, None, :], (bsz, h, n))
        y, new_ssm = ssd_decode_step(q, k, v, log_a, state["ssm"])
        y = y + xh.astype(jnp.float32) * p["d_skip"][None, :, None]
        y = y.reshape(bsz, d_in).astype(x.dtype) * jax.nn.silu(z)
        out = _einsum("be,ed->bd", y, p["out_proj"]).astype(x.dtype)
        return out, {"ssm": new_ssm, "conv": window[:, 1:]}


# ---------------------------------------------------------------------------
# mLSTM mixer (xLSTM matrix memory; sigmoid input gate for stability —
# documented simplification of the exponential gate).
#   C_t = f_t C + i_t v k^T ; n_t = f_t n + i_t k ; h = C q / max(|n.q|, 1)
# Implemented on the shared SSD primitive with v augmented by a ones column
# (the normalizer is just one extra value channel).
# ---------------------------------------------------------------------------

class MLSTM:
    def specs(self, cfg: ModelConfig) -> Dict[str, Spec]:
        d, h, hd = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
        dt = dtype_of(cfg)
        return {
            "wq": Spec((d, h, hd), ("embed", "heads", "head_dim"), dt, fan_in=d),
            "wk": Spec((d, h, hd), ("embed", "heads", "head_dim"), dt, fan_in=d),
            "wv": Spec((d, h, hd), ("embed", "heads", "head_dim"), dt, fan_in=d),
            "w_if": Spec((d, 2, h), ("embed", None, "heads"), jnp.float32, "small_normal", fan_in=d),
            "b_if": Spec((2, h), (None, "heads"), jnp.float32, "zeros"),
            "wo": Spec((h, hd, d), ("heads", "head_dim", "embed"), dt, fan_in=d),
        }

    def init_state(self, cfg: ModelConfig, batch: int, _max_context: int):
        h, hd = cfg.num_heads, cfg.resolved_head_dim
        return {
            "c": Spec((batch, h, hd, hd + 1),
                      ("batch", None, None, None), jnp.float32, "zeros"),
        }

    def _gates(self, p, x):
        gf = _einsum("...d,dgh->...gh", x, p["w_if"]) + p["b_if"]
        i_gate = jax.nn.sigmoid(gf[..., 0, :])
        log_f = jax.nn.log_sigmoid(gf[..., 1, :])
        return i_gate, log_f

    def _qkv(self, p, x, cfg):
        hd = cfg.resolved_head_dim
        q = _einsum("...d,dhk->...hk", x, p["wq"]).astype(x.dtype) * (hd ** -0.5)
        k = _einsum("...d,dhk->...hk", x, p["wk"]).astype(x.dtype) * (hd ** -0.25)
        v = _einsum("...d,dhk->...hk", x, p["wv"]).astype(x.dtype)
        return q, k, v

    @staticmethod
    def _read(y):
        """y [..., hd+1] -> normalized h (last channel = normalizer n.q)."""
        num, den = y[..., :-1], y[..., -1:]
        return num / jnp.maximum(jnp.abs(den), 1.0)

    def fwd_seq(self, p, x, ctx, cfg: ModelConfig):
        b, t, _ = x.shape
        q, k, v = self._qkv(p, x, cfg)
        i_gate, log_f = self._gates(p, x)              # [b,t,h]
        v_aug = jnp.concatenate(
            [v.astype(jnp.float32), jnp.ones(v.shape[:-1] + (1,), jnp.float32)],
            axis=-1) * i_gate[..., None]
        y, final = ssd_chunked(q, k, v_aug, log_f, cfg.ssm.chunk_size)
        hh = self._read(y).astype(x.dtype)
        out = _einsum("bthk,hkd->btd", hh, p["wo"]).astype(x.dtype)
        return out, {"c": final}

    def fwd_dec(self, p, x, state, shared, cfg: ModelConfig):
        q, k, v = self._qkv(p, x, cfg)
        i_gate, log_f = self._gates(p, x)              # [b,h]
        v_aug = jnp.concatenate(
            [v.astype(jnp.float32), jnp.ones(v.shape[:-1] + (1,), jnp.float32)],
            axis=-1) * i_gate[..., None]
        y, new_c = ssd_decode_step(q, k, v_aug, log_f, state["c"])
        hh = self._read(y).astype(x.dtype)
        out = _einsum("bhk,hkd->bd", hh, p["wo"]).astype(x.dtype)
        return out, {"c": new_c}


# ---------------------------------------------------------------------------
# sLSTM (scalar memory, per-head block-diagonal recurrence). Strictly
# sequential -> lax.scan over time; this is inherent to sLSTM.
# ---------------------------------------------------------------------------

class SLSTM:
    def specs(self, cfg: ModelConfig) -> Dict[str, Spec]:
        d, h, hd = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
        dt = jnp.float32  # recurrent cell in fp32
        return {
            "w": Spec((d, 4, h, hd), ("embed", None, "heads", "head_dim"), dt, fan_in=d),
            "r": Spec((h, 4, hd, hd), ("heads", None, "head_dim", None), dt, "small_normal", fan_in=hd),
            "b": Spec((4, h, hd), (None, "heads", "head_dim"), dt, "zeros"),
            "wo": Spec((h, hd, d), ("heads", "head_dim", "embed"), dtype_of(cfg), fan_in=d),
        }

    def init_state(self, cfg: ModelConfig, batch: int, _max_context: int):
        h, hd = cfg.num_heads, cfg.resolved_head_dim
        z = lambda: Spec((batch, h, hd), ("batch", "heads", None), jnp.float32, "zeros")
        return {"c": z(), "n": z(), "h": z()}

    @staticmethod
    def _cell(p, wx, state):
        """wx [B,4,H,hd] pre-activations; state {c,n,h}."""
        rec = _einsum("bhk,hgkl->bghl", state["h"], p["r"])
        za = wx + rec + p["b"][None]
        z = jnp.tanh(za[:, 0])
        i = jax.nn.sigmoid(za[:, 1])
        f = jax.nn.sigmoid(za[:, 2])
        o = jax.nn.sigmoid(za[:, 3])
        c = f * state["c"] + i * z
        n = f * state["n"] + i
        h = o * c / jnp.maximum(jnp.abs(n), 1.0)
        return {"c": c, "n": n, "h": h}

    TIME_CHUNK = 64

    def fwd_seq(self, p, x, ctx, cfg: ModelConfig):
        b, t, _ = x.shape
        wx = _einsum("btd,dghk->btghk", x, p["w"])     # [b,t,4,h,hd]
        h_, hd_ = cfg.num_heads, cfg.resolved_head_dim
        zeros = jnp.zeros((b, h_, hd_), jnp.float32)
        state = {"c": zeros, "n": zeros, "h": zeros}

        def step(s, wxt):
            s2 = self._cell(p, wxt, s)
            return s2, s2["h"]

        ck = self.TIME_CHUNK
        while t % ck:
            ck -= 1

        @jax.checkpoint
        def chunk_body(s, wxc):
            # checkpointed: AD saves only the (c, n, h) carry per chunk and
            # recomputes the per-step residuals in backward — without this,
            # differentiating the T-step scan stores O(T) step residuals
            # (~50 GiB/layer at 4k tokens; EXPERIMENTS.md §Perf iter. 4).
            return jax.lax.scan(step, s, wxc)

        wxc = jnp.moveaxis(wx, 1, 0).reshape(
            (t // ck, ck) + wx.shape[:1] + wx.shape[2:])
        state, hs = jax.lax.scan(chunk_body, state, wxc)
        hs = hs.reshape((t,) + hs.shape[2:])
        hs = jnp.moveaxis(hs, 0, 1).astype(x.dtype)    # [b,t,h,hd]
        out = _einsum("bthk,hkd->btd", hs, p["wo"]).astype(x.dtype)
        return out, state

    def fwd_dec(self, p, x, state, shared, cfg: ModelConfig):
        wx = _einsum("bd,dghk->bghk", x, p["w"])
        s2 = self._cell(p, wx, state)
        out = _einsum("bhk,hkd->bd", s2["h"].astype(x.dtype), p["wo"]).astype(x.dtype)
        return out, s2
