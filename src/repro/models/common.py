"""Parameter-spec system: one tree of ``Spec`` drives init, abstract
(ShapeDtypeStruct) instantiation for the dry-run, and sharding resolution.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import ShardingRules, sharding_for


@dataclasses.dataclass(frozen=True)
class Spec:
    """Declarative parameter: shape + logical axes + init."""
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    dtype: Any = jnp.float32
    init: str = "normal"          # normal | zeros | ones | small_normal
    fan_in: int = 0               # for scaled init; 0 -> shape[0] heuristic

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)

    def materialize(self, key) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "neg_ones":
            return jnp.full(self.shape, -1, self.dtype)
        fan = self.fan_in or (self.shape[-2] if len(self.shape) >= 2 else self.shape[-1])
        scale = 1.0 / math.sqrt(max(fan, 1))
        if self.init == "small_normal":
            scale *= 0.1
        x = jax.random.normal(key, self.shape, jnp.float32) * scale
        return x.astype(self.dtype)

    def abstract(self, mesh=None, rules=None, memory_kind=None) -> jax.ShapeDtypeStruct:
        if mesh is None:
            return jax.ShapeDtypeStruct(self.shape, self.dtype)
        sh = sharding_for(self.logical, self.shape, mesh, rules, memory_kind)
        return jax.ShapeDtypeStruct(self.shape, self.dtype, sharding=sh)


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def tree_init(specs, key) -> Any:
    """Materialize a Spec tree into a param pytree (deterministic key split)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [s.materialize(k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def tree_abstract(specs, mesh=None, rules=None, memory_kind=None) -> Any:
    return jax.tree.map(
        lambda s: s.abstract(mesh, rules, memory_kind), specs, is_leaf=is_spec)


def tree_shardings(specs, mesh, rules=None, memory_kind=None) -> Any:
    return jax.tree.map(
        lambda s: sharding_for(s.logical, s.shape, mesh, rules, memory_kind),
        specs, is_leaf=is_spec)


def tree_bytes(specs) -> int:
    return sum(
        int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
        for s in jax.tree.leaves(specs, is_leaf=is_spec))


def tree_param_count(specs) -> int:
    return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(specs, is_leaf=is_spec))


def stack_specs(spec_tree, n: int, stack_logical: str = "stack"):
    """Prepend a stacked-layer dim of size n to every Spec in a tree."""
    def f(s: Spec) -> Spec:
        return Spec((n,) + s.shape, (stack_logical,) + s.logical, s.dtype, s.init, s.fan_in)
    return jax.tree.map(f, spec_tree, is_leaf=is_spec)


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)
