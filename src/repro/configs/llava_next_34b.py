"""LLaVA-NeXT 34B — anyres tiling VLM. [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
Backbone only: the anyres vision frontend is a STUB — ``input_specs()``
supplies precomputed patch embeddings [B, 2880, d_model]
(4 tiles + 1 base image x 576 patches) concatenated as a prefix.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    head_dim=128,
    num_image_patches=2880,
    source="[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]",
)
