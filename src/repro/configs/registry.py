"""Architecture registry: ``--arch <id>`` -> ModelConfig, plus shape cells.

``CELLS`` enumerates every (arch x shape) dry-run cell, applying the
documented skip rules (DESIGN.md §4):
  - long_500k only for sub-quadratic archs (SWA / SSM / hybrid).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, SHAPES_BY_NAME

from repro.configs.kimi_k2_1t_a32b import CONFIG as _kimi
from repro.configs.moonshot_v1_16b_a3b import CONFIG as _moonshot
from repro.configs.h2o_danube_3_4b import CONFIG as _danube
from repro.configs.granite_3_8b import CONFIG as _granite
from repro.configs.phi3_medium_14b import CONFIG as _phi3
from repro.configs.llama3_8b import CONFIG as _llama3
from repro.configs.xlstm_1_3b import CONFIG as _xlstm
from repro.configs.llava_next_34b import CONFIG as _llava
from repro.configs.jamba_v0_1_52b import CONFIG as _jamba
from repro.configs.whisper_medium import CONFIG as _whisper

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _kimi, _moonshot, _danube, _granite, _phi3,
        _llama3, _xlstm, _llava, _jamba, _whisper,
    )
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def supports_long_context(cfg: ModelConfig) -> bool:
    """Sub-quadratic decode: SWA, SSM, or hybrid archs."""
    return bool(cfg.sliding_window) or cfg.family in ("ssm", "hybrid")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch x shape) cell."""
    if shape.name == "long_500k" and not supports_long_context(cfg):
        return False, "pure full-attention arch: long_500k needs sub-quadratic attention"
    return True, ""


def all_cells() -> List[Tuple[ModelConfig, ShapeConfig]]:
    """Every runnable (arch x shape) dry-run cell, in registry order."""
    cells = []
    for cfg in ARCHS.values():
        for shape in SHAPES:
            ok, _ = shape_applicable(cfg, shape)
            if ok:
                cells.append((cfg, shape))
    return cells


def skipped_cells() -> List[Tuple[str, str, str]]:
    out = []
    for cfg in ARCHS.values():
        for shape in SHAPES:
            ok, why = shape_applicable(cfg, shape)
            if not ok:
                out.append((cfg.name, shape.name, why))
    return out


__all__ = [
    "ARCHS", "SHAPES", "SHAPES_BY_NAME", "get_arch",
    "shape_applicable", "all_cells", "skipped_cells", "supports_long_context",
]
