from repro.configs.base import (
    ModelConfig, MoEConfig, SSMConfig, ShapeConfig, RuntimeConfig,
    SHAPES, SHAPES_BY_NAME, scaled_config,
)
from repro.configs.registry import (
    ARCHS, get_arch, all_cells, skipped_cells, shape_applicable,
    supports_long_context,
)
