"""xLSTM 1.3B — sLSTM + mLSTM blocks. [arXiv:2405.04517; unverified]

48L d_model=2048 4H (kv=4) d_ff=0 vocab=50304. Matrix-memory mLSTM blocks
with one sLSTM block every 8 layers (the assignment lists both kinds).
No KV cache: decode state is O(1) in context -> long_500k runs.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,                       # xLSTM blocks carry their own projections
    vocab_size=50304,
    head_dim=512,                 # 2048 / 4
    ssm=SSMConfig(kind="mlstm", chunk_size=128, slstm_period=8),
    source="[arXiv:2405.04517; unverified]",
)
