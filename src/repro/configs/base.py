"""Config system: model architecture, input shapes, hardware, runtime.

Every assigned architecture is a ``ModelConfig`` in its own module
(``src/repro/configs/<id>.py``) and registered in ``configs.registry``.
Configs are plain frozen dataclasses — hashable, picklable, and safe to use
as static args to ``jax.jit``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts sub-config (GShard-style capacity routing)."""
    num_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    min_capacity: int = 8
    router_jitter: float = 0.0
    # every `period`-th layer is MoE (1 = all layers, 2 = alternating, ...)
    period: int = 1


@dataclass(frozen=True)
class SSMConfig:
    """State-space / recurrent block sub-config (mLSTM / sLSTM / mamba)."""
    kind: str = "mlstm"           # "mlstm" | "slstm" | "mamba"
    d_state: int = 16             # mamba SSM state size
    d_conv: int = 4               # mamba conv width
    expand: int = 2               # mamba expansion factor
    chunk_size: int = 128         # chunkwise-parallel scan chunk
    # For xLSTM: one sLSTM block every `slstm_period` layers (0 = never).
    slstm_period: int = 0


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. Field names follow the assignment table."""
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int                     # dense FFN hidden (0 = no separate FFN)
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    # attention
    rope_theta: float = 500000.0
    sliding_window: int = 0       # 0 = full attention; >0 = SWA window
    # sub-configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (jamba): attention every `attn_period` layers, rest are SSM.
    attn_period: int = 0          # 0 = all layers attention
    # enc-dec (whisper)
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    max_source_len: int = 1500    # encoder output length used for decode cells
    # vlm (llava)
    num_image_patches: int = 0    # prefix patch embeddings supplied by stub
    # numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # provenance, e.g. "[arXiv:2407.21783; unverified]"
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind, length == num_layers (decoder stack).

        Kinds: "attn", "attn_moe", "ssm", "ssm_moe".
        """
        kinds = []
        for i in range(self.num_layers):
            if self.attn_period:
                # jamba-style: attention on every attn_period-th layer
                # (layer index attn_period-1 within each group), SSM otherwise.
                is_attn = (i % self.attn_period) == self.attn_period - 1
            elif self.family == "ssm":
                is_attn = False
            else:
                is_attn = True
            base = "attn" if is_attn else "ssm"
            if self.moe is not None and (i % self.moe.period) == (self.moe.period - 1):
                base += "_moe"
            kinds.append(base)
        return tuple(kinds)

    def _layer_params(self, kind: str, active: bool = False) -> int:
        """Params of one decoder layer of the given kind (norms + mixer +
        FFN/MoE). ``active=True`` counts only the params touched per token
        (MoE: router + top_k experts instead of all num_experts)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        attn = d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
        total = 2 * d  # norms
        total += attn if kind.startswith("attn") else self._ssm_params()
        if kind.endswith("_moe"):
            m = self.moe
            n_e = m.top_k if active else m.num_experts
            total += d * m.num_experts + n_e * 3 * d * m.d_expert
        elif self.d_ff:
            total += 3 * d * self.d_ff  # SwiGLU
        return total

    def param_count(self) -> int:
        """Analytic parameter count (embedding + decoder stack [+ encoder])."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
        total = embed
        for kind in self.layer_kinds():
            total += self._layer_params(kind)
        if self.is_encoder_decoder:
            # encoder self-attn + FFN + cross-attn params in decoder
            enc = self.num_encoder_layers * (attn + 2 * d * self.d_ff + 2 * d)
            cross = self.num_layers * (attn + d)
            total += enc + cross
        return total

    def _ssm_params(self) -> int:
        d = self.d_model
        s = self.ssm or SSMConfig()
        if s.kind == "mamba":
            d_in = s.expand * d
            return (d * 2 * d_in            # in_proj (x, z)
                    + d_in * s.d_conv       # conv
                    + d_in * (2 * s.d_state + 1) + d_in  # B,C,dt proj + A,D
                    + d_in * d)             # out_proj
        # mLSTM: q,k,v,o projections + i/f gates (matches MLSTM.specs)
        hd = self.resolved_head_dim
        nh = self.num_heads
        return 4 * d * (nh * hd) + 2 * d * nh + 2 * nh

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of num_experts)."""
        if self.moe is None:
            return self.param_count()
        total = self.param_count()
        m = self.moe
        n_moe_layers = sum(1 for k in self.layer_kinds() if k.endswith("_moe"))
        expert_params = n_moe_layers * m.num_experts * 3 * self.d_model * m.d_expert
        active_expert = n_moe_layers * m.top_k * 3 * self.d_model * m.d_expert
        return total - expert_params + active_expert

    @property
    def dtype_bytes(self) -> int:
        return {"float32": 4, "float64": 8}.get(self.dtype, 2)

    def bytes_for_layer(self, i: int, dtype_bytes: Optional[int] = None) -> int:
        """Parameter bytes of decoder layer ``i`` — the layer-granular remap
        unit. For MoE layers this includes ALL experts; expert-granular
        plans charge ``expert_bytes()`` per donated expert instead."""
        b = self.dtype_bytes if dtype_bytes is None else dtype_bytes
        return self._layer_params(self.layer_kinds()[i]) * b

    def expert_bytes(self, dtype_bytes: Optional[int] = None) -> int:
        """Bytes of ONE expert's FFN weights (``3 * d_model * d_expert``
        SwiGLU params) — the expert-granular remap unit. 0 for non-MoE."""
        if self.moe is None:
            return 0
        b = self.dtype_bytes if dtype_bytes is None else dtype_bytes
        return 3 * self.d_model * self.moe.d_expert * b

    def num_moe_layers(self) -> int:
        return sum(1 for k in self.layer_kinds() if k.endswith("_moe"))

    def active_params_per_token(self) -> int:
        """Per-layer decomposition of ``active_param_count`` — embedding plus
        each layer's per-token-active params. Equal to ``active_param_count``
        by construction; exists so PerfModel and expert plans can charge
        ``top_k`` experts per MoE layer rather than whole-layer totals."""
        d = self.d_model
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = embed
        for kind in self.layer_kinds():
            total += self._layer_params(kind, active=True)
        if self.is_encoder_decoder:
            hd = self.resolved_head_dim
            attn = (d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd)
                    + (self.num_heads * hd) * d)
            enc = self.num_encoder_layers * (attn + 2 * d * self.d_ff + 2 * d)
            cross = self.num_layers * (attn + d)
            total += enc + cross
        return total


@dataclass(frozen=True)
class ShapeConfig:
    """An assigned (seq_len, global_batch) input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}


@dataclass(frozen=True)
class RuntimeConfig:
    """Knobs for a concrete run (training or serving)."""
    # parallelism
    mesh_shape: Tuple[int, ...] = (1, 1)
    mesh_axes: Tuple[str, ...] = ("data", "model")
    fsdp_over_pod: bool = True        # shard params over pod axis too (>=1T)
    context_parallel: bool = True     # shard long-seq KV over model axis
    # training
    remat_policy: str = "dots_saveable"  # none|full|dots_saveable
    microbatches: int = 1
    optimizer: str = "adamw"          # adamw | adafactor
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # serving / MIRAGE
    page_size: int = 16               # tokens per KV page
    max_remap_fraction: float = 0.5   # paper: capped remapping percentage
    remap_tiers: Tuple[float, ...] = (0.0, 0.125, 0.25, 0.5)
    double_buffer: bool = True        # beta=2 (m = alpha+2)
    victim_policy: str = "mru"        # mru | lru
    reversion_hysteresis: float = 0.2 # free-fraction above which we revert
    dynamic_reversion: bool = True


def scaled_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced-size config of the same family for CPU smoke tests."""
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(
            moe,
            num_experts=min(moe.num_experts, 8),
            top_k=min(moe.top_k, 2),
            d_expert=min(moe.d_expert, 64),
        )
    small = dataclasses.replace(
        cfg,
        num_layers=min(cfg.num_layers, 4),
        num_encoder_layers=min(cfg.num_encoder_layers, 2),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        head_dim=32,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        num_image_patches=min(cfg.num_image_patches, 16) if cfg.num_image_patches else 0,
        max_source_len=64,
        moe=moe,
        dtype="float32",
    )
    if cfg.attn_period:
        small = dataclasses.replace(small, attn_period=min(cfg.attn_period, 4))
    if cfg.ssm is not None:
        small = dataclasses.replace(
            small, ssm=dataclasses.replace(cfg.ssm, chunk_size=16, d_state=8))
    return dataclasses.replace(small, **overrides)
