"""Jamba v0.1 52B — Mamba+attention 1:7 interleave, MoE. [arXiv:2403.19887; hf]

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2 on
every other layer. Attention on every 8th layer (1 attn : 7 mamba).
Hybrid -> long_500k runs (only 4 attention layers hold KV; mamba state O(1)).
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    head_dim=128,
    attn_period=8,                # layers 7,15,23,31 are attention
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=14336, period=2),
    ssm=SSMConfig(kind="mamba", d_state=16, d_conv=4, expand=2, chunk_size=128),
    source="[arXiv:2403.19887; hf]",
)
