"""h2o-danube-3-4b — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified]

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000, SWA window=4096.
SWA makes decode memory sub-quadratic in context -> long_500k cell runs with a
ring-buffer KV of the window size.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    head_dim=120,                 # 3840 / 32
    sliding_window=4096,
    source="[arXiv:2401.16818; unverified]",
)
