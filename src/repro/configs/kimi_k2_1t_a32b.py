"""Kimi K2 — trillion-param MoE. [arXiv:2501.kimi2; unverified]

61L d_model=7168 64H (GQA kv=8) per-expert d_ff=2048 vocab=163840,
MoE 384 experts top-8 (all layers MoE per the assignment table).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=0,                       # every layer is MoE
    vocab_size=163840,
    head_dim=112,                 # 7168 / 64
    moe=MoEConfig(num_experts=384, top_k=8, d_expert=2048, period=1),
    source="[arXiv:2501.kimi2; unverified]",
)
