"""Whisper Medium — encoder-decoder, conv frontend (stub). [arXiv:2212.04356; unverified]

24L (decoder; + 24 encoder layers) d_model=1024 16H (kv=16 == MHA)
d_ff=4096 vocab=51865. The conv1d+mel frontend is a STUB — ``input_specs()``
supplies precomputed frame embeddings [B, frames, d_model].
Decode cells run the decoder backbone with self-KV = cell seq_len and
cross-KV = 1500 encoder frames (beyond the trained 448-token max; exercised
as a backbone systems cell).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    head_dim=64,
    is_encoder_decoder=True,
    num_encoder_layers=24,
    max_source_len=1500,
    source="[arXiv:2212.04356; unverified]",
)
