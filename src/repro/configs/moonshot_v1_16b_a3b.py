"""kimi/moonlight 16B-A3B MoE. [hf:moonshotai/Moonlight-16B-A3B; hf]

48L d_model=2048 16H (GQA kv=16 == MHA) per-expert d_ff=1408 vocab=163840,
MoE 64 experts top-6.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=0,
    vocab_size=163840,
    head_dim=128,
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408, period=1),
    source="[hf:moonshotai/Moonlight-16B-A3B; hf]",
)
